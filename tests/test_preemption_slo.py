"""Multi-tenant SLO classes, the preemption-policy menu, and goodput.

Three layers under test:

  * GOLDEN PIN — ``preemption="sacrifice"`` with a single (retagged)
    tenant class is the pre-menu engine, bit for bit: every colocated
    and disagg case in ``tests/golden/core_golden.json`` must reproduce
    exactly even though the eviction path now routes through
    ``PreemptionPolicy`` and every record carries an ``SLOClass``.
  * MECHANICS — the victim orders (``recent-first`` vs
    ``lowest-priority-first``) and mechanisms (``sacrifice`` vs
    ``swap``) behave as advertised on seeded traces: priority eviction
    shields the high-priority tenant's TTFT p95, swap preserves decode
    progress (faster drain, balanced swap-out/swap-in counters, no
    decode-role re-fetch, first token never re-stamped).
  * GOODPUT — ``search(objective="goodput")`` ranks by per-class SLO
    attainment through both the exact and multi-fidelity paths, and the
    fluid screen's survivor frontier contains the exact winner on a
    seeded two-class trace.
"""

import json
import math
import os

import pytest

from repro.core import (ApexSearch, CollectiveModel, MultiFidelitySearch,
                        ProfileStore, SLOClass, generate_schemes, get_trace,
                        h100_node, ir_from_hf_config, make_preemption,
                        map_scheme, mixed_trace)
from repro.core.batching import BatchingModule, BatchingPolicy
from repro.core.engine import PreemptionPolicy, SacrificePolicy, SwapPolicy
from repro.core.metrics import p95
from repro.core.profiles import AnalyticBackend
from repro.core.simulator import PlanSimulator
from repro.core.trace import Request
from repro.disagg import DisaggSimulator, generate_disagg_schemes, \
    map_disagg_scheme

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "core_golden.json")
SMALL = dict(hidden_size=256, num_hidden_layers=4, num_attention_heads=8,
             num_key_value_heads=4, intermediate_size=1024, vocab_size=1024)

POLICIES = {
    "continuous": BatchingPolicy(),
    "chunked": BatchingPolicy(chunked_prefill=128),
    "static": BatchingPolicy(mode="static", max_batch_size=8),
    "capped": BatchingPolicy(max_batch_size=4, fast_forward=False),
}

# a single tenant class with a nonzero priority and no targets: retagging
# the whole trace with it must not move a single float
ONE_TENANT = [SLOClass(name="tenant", priority=3)]


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def ctx():
    model = ir_from_hf_config(SMALL, name="tiny")
    cluster = h100_node(8)
    return model, cluster, ProfileStore(AnalyticBackend(cluster)), \
        CollectiveModel(cluster)


def _colocated_scheme(model, dp):
    for s in generate_schemes(model, 8, quant="fp16"):
        if (s.model_dp == dp and s.pp_stages == 1
                and s.is_feasible_for_current_systems()):
            return s
    raise RuntimeError("no scheme")


def _disagg_scheme(model, cluster, mode):
    for s in generate_disagg_schemes(model, cluster, max_plans=100000,
                                     transfer_mode=mode):
        if (s.prefill_devices == 4 and s.decode_devices == 4
                and s.prefill.model_dp == 1 and s.decode.model_dp == 1
                and s.prefill.pp_stages == 1 and s.decode.pp_stages == 1):
            return s
    raise RuntimeError("no disagg scheme")


def _assert_report_matches(rep, want):
    for field, expect in want.items():
        if field == "records":
            got = sorted((r.rid, r.first_token_time, r.finish_time,
                          r.preemptions, r.refetch_s) for r in rep.records)
            assert got == [tuple(r) for r in expect]
        else:
            assert getattr(rep, field) == expect, field


def const_cost(per_token=1e-3, per_iter=5e-3):
    def step_cost(w):
        t = per_iter + per_token * w.total_tokens
        return t, t * 100.0
    return step_cost


def mk_requests(specs, slo=None):
    kw = {"slo_class": slo} if slo is not None else {}
    return [Request(rid=i, arrival=a, context_len=c, gen_len=g, **kw)
            for i, (a, c, g) in enumerate(specs)]


# ---------------------------------------------------------------------------
# golden pin: explicit sacrifice + a single class == the frozen engine
# ---------------------------------------------------------------------------

def test_sacrifice_single_class_matches_colocated_goldens(golden, ctx):
    model, cluster, store, coll = ctx
    plans = {dp: map_scheme(_colocated_scheme(model, dp), cluster)
             for dp in (1, 2)}
    for case in golden["colocated"]:
        reqs = get_trace(case["trace"], arrival_rate=case["rate"], seed=11,
                         num_requests=48)
        sim = PlanSimulator(plans[case["dp"]], store, coll)
        rep = sim.simulate(reqs, policy=POLICIES[case["policy"]],
                           keep_records=True, preemption="sacrifice",
                           slo_classes=ONE_TENANT)
        _assert_report_matches(rep, case["report"])


def test_sacrifice_single_class_matches_disagg_goldens(golden, ctx):
    model, cluster, store, coll = ctx
    for case in golden["disagg"]:
        scheme = _disagg_scheme(model, cluster, case["mode"])
        plan = map_disagg_scheme(scheme, cluster)
        reqs = get_trace(case["trace"], arrival_rate=case["rate"], seed=11,
                         num_requests=48)
        sim = DisaggSimulator(plan, store, coll)
        rep = sim.simulate(reqs, keep_records=True, congestion=False,
                           reprefill_occupancy=False,
                           preemption="sacrifice", slo_classes=ONE_TENANT)
        _assert_report_matches(rep, case["report"])


# ---------------------------------------------------------------------------
# preemption menu: parsing + labels
# ---------------------------------------------------------------------------

def test_make_preemption_menu():
    assert isinstance(make_preemption(None), SacrificePolicy)
    assert make_preemption(None).label() == "sacrifice/recent"
    assert make_preemption("swap").label() == "swap/recent"
    p = make_preemption("sacrifice/lowest-priority-first")
    assert isinstance(p, SacrificePolicy) and p.victim == "priority"
    assert make_preemption("swap/lifo").victim == "recent"
    inst = SwapPolicy(victim="lowest-priority")
    assert make_preemption(inst) is inst
    with pytest.raises(ValueError, match="mechanism"):
        make_preemption("migrate")
    with pytest.raises(ValueError, match="victim"):
        make_preemption("swap/oldest")
    with pytest.raises(NotImplementedError):
        PreemptionPolicy().evict(None, None, 0.0)


# ---------------------------------------------------------------------------
# victim order: priority eviction shields the high-priority tenant
# ---------------------------------------------------------------------------

def _class_ttft_p95(res):
    by_cls = {}
    for rec in res.records:
        by_cls.setdefault(rec.slo_class.name, []).append(rec.ttft)
    return {name: p95(v) for name, v in by_cls.items()}


def test_lowest_priority_first_wins_ttft_p95():
    """Seeded two-class trace under KV pressure: with
    ``lowest-priority-first`` eviction the high-priority class beats the
    low-priority class on TTFT p95, and beats its own TTFT p95 under the
    class-blind ``recent-first`` order."""
    hi = SLOClass("hi", priority=2)
    lo = SLOClass("lo", priority=0)
    reqs = mixed_trace([("chat", 12.0, hi, 24), ("chat", 12.0, lo, 24)],
                       seed=7)
    cap = max(r.context_len + r.gen_len for r in reqs) + 200

    def run(spec):
        return BatchingModule(cap, BatchingPolicy(),
                              preemption=spec).run(reqs, const_cost())

    prio = run("sacrifice/lowest-priority-first")
    recent = run("sacrifice/recent-first")
    assert prio.preemptions > 0 and recent.preemptions > 0
    assert _class_ttft_p95(prio)["hi"] < _class_ttft_p95(prio)["lo"]
    assert _class_ttft_p95(prio)["hi"] < _class_ttft_p95(recent)["hi"]


# ---------------------------------------------------------------------------
# mechanism: swap preserves progress and is counted separately
# ---------------------------------------------------------------------------

def test_swap_counters_and_progress():
    reqs = mk_requests([(0.0, 60, 40)] * 8)

    sac = BatchingModule(300, BatchingPolicy(),
                         preemption="sacrifice").run(reqs, const_cost())
    swp = BatchingModule(300, BatchingPolicy(), preemption="swap",
                         swap_cost=lambda r, kv: (0.01, 0.5)).run(
        reqs, const_cost())

    # sacrifice never touches the swap counters
    assert sac.preemptions > 0
    assert sac.swap_outs == sac.swap_ins == 0 and sac.kv_swap_s == 0.0
    assert all(r.swaps == 0 and r.swap_s == 0.0 for r in sac.records)

    # every swap-out is paid for, restored, and attributed to its victim
    assert swp.swap_outs > 0
    assert swp.swap_ins == swp.swap_outs == swp.preemptions
    assert swp.kv_swap_s == pytest.approx(0.01 * swp.swap_outs)
    assert sum(r.swaps for r in swp.records) == swp.swap_outs
    assert sum(r.swap_s for r in swp.records) == pytest.approx(swp.kv_swap_s)

    # parked KV means no prompt recompute: the swap run drains faster
    assert swp.total_time < sac.total_time


def test_decode_swap_skips_refetch_and_keeps_first_token():
    """In the disagg decode role, only sacrifice re-fetches shipped
    prompt KV; a swap victim's KV is parked on the host, so no re-fetch
    is charged and its first token is never re-stamped."""
    reqs = mk_requests([(0.0, 200, 5), (0.0, 200, 60)])
    sac = BatchingModule(404, BatchingPolicy(), role="decode").run(
        reqs, const_cost())
    swp = BatchingModule(404, BatchingPolicy(), role="decode",
                         preemption="swap",
                         swap_cost=lambda r, kv: (0.02, 0.0)).run(
        reqs, const_cost())
    assert sac.preemptions > 0 and sac.kv_refetch_s > 0.0
    assert swp.swap_outs > 0 and swp.kv_refetch_s == 0.0
    victim = next(r for r in swp.records if r.swaps > 0)
    assert victim.first_token_time == 0.0  # admitted at t=0, never re-set


# ---------------------------------------------------------------------------
# per-class reporting + goodput
# ---------------------------------------------------------------------------

CHAT_SLO = SLOClass("chat", priority=1, ttft_target_s=0.005,
                    tpot_target_s=3e-4)
SUMM_SLO = SLOClass("summarization", priority=0, ttft_target_s=0.03)


def _two_class_trace():
    return mixed_trace([("chat", 4.0, CHAT_SLO, 48),
                        ("summarization", 1.0, SUMM_SLO, 16)], seed=7)


def test_report_per_class_percentiles_and_goodput(ctx):
    model, cluster, store, coll = ctx
    plan = map_scheme(_colocated_scheme(model, 1), cluster)
    rep = PlanSimulator(plan, store, coll).simulate(
        _two_class_trace(), keep_records=True)

    assert [c.name for c in rep.class_reports] == ["chat", "summarization"]
    assert rep.ttft_p50 <= rep.ttft_p95 <= rep.ttft_p99
    assert rep.tpot_p50 <= rep.tpot_p95 <= rep.tpot_p99
    met = sum(c.slo_met for c in rep.class_reports)
    assert 0 < met <= 64
    assert rep.goodput_rps == pytest.approx(met / rep.e2e_latency)
    assert rep.goodput_rps == pytest.approx(
        sum(c.goodput_rps for c in rep.class_reports))
    assert rep.sacrifices == rep.preemptions - rep.swap_outs

    text = str(rep)
    assert "TTFT p50/p95/p99" in text and "TPOT p50/p95/p99" in text
    assert "[chat p1]" in text and "[summarization p0]" in text
    assert "goodput=" in rep.summary()


def test_classless_goodput_degrades_to_request_throughput(ctx):
    """With no SLO targets anywhere, every finished request counts:
    goodput is plain request throughput."""
    model, cluster, store, coll = ctx
    plan = map_scheme(_colocated_scheme(model, 1), cluster)
    reqs = get_trace("chat", arrival_rate=4.0, seed=11, num_requests=32)
    rep = PlanSimulator(plan, store, coll).simulate(reqs)
    assert rep.goodput_rps == pytest.approx(len(reqs) / rep.e2e_latency)


# ---------------------------------------------------------------------------
# goodput objective: exact, multi-fidelity, and fluid-screen containment
# ---------------------------------------------------------------------------

def test_goodput_search_exact_and_multifid_containment():
    model = ir_from_hf_config(SMALL, name="tiny")
    search = ApexSearch(model, h100_node(4))
    reqs = _two_class_trace()

    res = search.search(reqs, objective="goodput")
    assert res.objective == "goodput"
    goodputs = [r.goodput_rps for r in res.all_reports if r.feasible]
    # the SLO targets bite: plans genuinely differ on goodput, and the
    # winner maximizes it
    assert min(goodputs) < max(goodputs)
    assert res.best.goodput_rps == pytest.approx(max(goodputs))
    assert [c.name for c in res.best.class_reports] == \
        ["chat", "summarization"]

    mf = MultiFidelitySearch(search)
    mres = mf.search(reqs, objective="goodput")
    survivor_labels = [mres.surrogate_reports[i].plan_label
                       for i in mres.survivor_indices]
    # the fluid screen's frontier contains the exact winner, and the
    # confirmed ranking recovers its goodput exactly
    assert res.best.plan_label in survivor_labels
    assert mres.best.goodput_rps == pytest.approx(res.best.goodput_rps)
