import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str, devices: int = 8, timeout: int = 420):
    """Run a test body in a fresh interpreter with N host devices.

    Multi-device shard_map/pjit tests need
    --xla_force_host_platform_device_count, which must be set before jax
    initializes — impossible in the already-running pytest process.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n"
            f"{res.stderr[-4000:]}")
    return res.stdout


@pytest.fixture
def subproc():
    return run_subprocess
