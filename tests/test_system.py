"""End-to-end behaviour tests for the APEX system (paper workflow)."""

import pytest

from repro.core import (ApexSearch, BatchingPolicy, compare_three_plans,
                        generate_schemes, get_trace, h100_node,
                        h100_multinode, heuristic_scheme, ir_from_hf_config)


LLAMA70B = dict(hidden_size=8192, num_hidden_layers=80,
                num_attention_heads=64, num_key_value_heads=8,
                intermediate_size=28672, vocab_size=128256)
MIXTRAL = dict(hidden_size=6144, num_hidden_layers=56,
               num_attention_heads=48, num_key_value_heads=8,
               intermediate_size=16384, num_local_experts=8,
               num_experts_per_tok=2, moe_intermediate_size=16384,
               vocab_size=32000)


@pytest.fixture(scope="module")
def llama():
    return ir_from_hf_config(LLAMA70B, name="llama-70b")


@pytest.fixture(scope="module")
def mixtral():
    return ir_from_hf_config(MIXTRAL, name="mixtral-8x22b")


def test_search_beats_or_matches_baseline(llama):
    cluster = h100_node(8)
    reqs = get_trace("chat", arrival_rate=8.0, num_requests=64)
    s = ApexSearch(llama, cluster)
    base = s.evaluate_baseline(reqs)
    res = s.search(reqs, feasible_only=False)
    assert res.best.e2e_latency <= base.e2e_latency * 1.0001
    assert res.num_feasible > 0
    assert res.best.feasible


def test_three_plan_comparison_structure(mixtral):
    cluster = h100_node(8)
    reqs = get_trace("creation", arrival_rate=4.0, num_requests=48)
    out = compare_three_plans(mixtral, cluster, reqs)
    # APEX optimal explores a superset of the feasible space
    assert out["apex_speedup"] >= out["feasible_speedup"] * 0.999
    assert out["baseline"].e2e_latency > 0
    assert out["feasible_optimal"].plan_label
    # the paper's observation: EP shows up for MoE models
    labels = [r.plan_label for r in out["search"].all_reports]
    assert any("ep" in l for l in labels)


def test_report_metrics_sane(llama):
    cluster = h100_node(8)
    reqs = get_trace("summarization", arrival_rate=1.0, num_requests=32)
    s = ApexSearch(llama, cluster)
    rep = s.evaluate_baseline(reqs)
    assert rep.e2e_latency > 0
    assert rep.ttft_mean > 0
    assert rep.tpot_mean > 0
    assert rep.ttft_p95 >= rep.ttft_mean * 0.5
    assert 0 < rep.mfu <= 1
    assert 0 < rep.mbu <= 1
    assert rep.total_energy > 0
    assert rep.throughput_tok_s > 0


def test_slo_constrained_search(llama):
    cluster = h100_node(8)
    reqs = get_trace("chat", arrival_rate=4.0, num_requests=48)
    s = ApexSearch(llama, cluster)
    res = s.search(reqs, objective="latency", slo_tpot_s=1.0)
    assert res.best.tpot_p95 <= 1.0


def test_energy_objective_differs(llama):
    """Energy-optimal may differ from latency-optimal (paper §4.2.4)."""
    cluster = h100_node(8)
    reqs = get_trace("summarization", arrival_rate=1.0, num_requests=32)
    s = ApexSearch(llama, cluster)
    lat = s.search(reqs, objective="latency")
    en = s.search(reqs, objective="energy")
    assert en.best.total_energy <= lat.best.total_energy * 1.0001


def test_multinode_baseline_uses_pp(llama):
    cluster = h100_multinode(2)
    scheme = heuristic_scheme(llama, 16, cluster)
    assert scheme.pp_stages == 2           # TP in node, PP across (paper)
    assert scheme.stage_devices == 8


def test_batching_policy_max_batch(llama):
    cluster = h100_node(8)
    reqs = get_trace("creation", arrival_rate=4.0, num_requests=32)
    s = ApexSearch(llama, cluster)
    uncapped = s.evaluate_baseline(reqs)
    capped = s.evaluate_baseline(
        reqs, policy=BatchingPolicy(max_batch_size=2))
    assert capped.peak_batch <= 2
    assert capped.e2e_latency >= uncapped.e2e_latency * 0.999
