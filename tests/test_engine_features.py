"""Satellite features riding on the event-engine refactor: per-pool
batching policies, SLO-aware SearchResult.top(), the throughput
objective, shared metrics helpers, and derived router drain rates."""

import pytest

from repro.core import (ApexSearch, BatchingPolicy, CollectiveModel,
                        ProfileStore, get_trace, h100_node,
                        ir_from_hf_config, percentile)
from repro.core.metrics import SimulationReport, p95
from repro.core.profiles import AnalyticBackend
from repro.core.search import OBJECTIVES, SearchResult
from repro.disagg import DisaggSimulator, generate_disagg_schemes, \
    map_disagg_scheme
from repro.serving.router import derive_drain_rate

SMALL = dict(hidden_size=256, num_hidden_layers=4, num_attention_heads=8,
             num_key_value_heads=4, intermediate_size=1024, vocab_size=1024)


def small_model():
    return ir_from_hf_config(SMALL, name="tiny")


# ---------------------------------------------------------------------------
# per-pool batching policies
# ---------------------------------------------------------------------------

def _shared_cluster_plan(model, cluster):
    scheme = next(s for s in generate_disagg_schemes(model, cluster,
                                                     max_plans=100000)
                  if s.prefill_devices == 4 and s.decode_devices == 4
                  and s.prefill.model_dp == 1 and s.decode.model_dp == 1)
    return map_disagg_scheme(scheme, cluster)


def test_per_pool_policies_drive_each_pool():
    """Chunked prefill on the prefill pool only: the prefill pool's
    iteration stream shows bounded prefill chunks while the decode pool
    runs plain continuous batching — and the run differs from the
    shared-policy run."""
    model = small_model()
    cluster = h100_node(8)
    plan = _shared_cluster_plan(model, cluster)
    store = ProfileStore(AnalyticBackend(cluster))
    coll = CollectiveModel(cluster)
    reqs = get_trace("summarization", arrival_rate=2.0, seed=1,
                     num_requests=16)

    sim = DisaggSimulator(plan, store, coll)
    shared = sim.simulate(reqs)
    chunked = sim.simulate(reqs,
                           prefill_policy=BatchingPolicy(chunked_prefill=64),
                           decode_policy=BatchingPolicy(max_batch_size=4))
    assert shared.feasible and chunked.feasible
    # chunking a 2.7k-token mean prompt into 64-token slices takes many
    # more prefill iterations
    assert chunked.iterations > shared.iterations
    assert chunked.peak_batch <= max(shared.peak_batch, 16)


def test_plan_level_pool_policies_respected():
    import dataclasses
    model = small_model()
    cluster = h100_node(8)
    plan = _shared_cluster_plan(model, cluster)
    plan = dataclasses.replace(
        plan, prefill_policy=BatchingPolicy(chunked_prefill=64))
    store = ProfileStore(AnalyticBackend(cluster))
    sim = DisaggSimulator(plan, store, CollectiveModel(cluster))
    reqs = get_trace("summarization", arrival_rate=2.0, seed=1,
                     num_requests=16)
    plan_pol = sim.simulate(reqs)
    explicit = sim.simulate(
        reqs, prefill_policy=BatchingPolicy(chunked_prefill=64))
    assert plan_pol.iterations == explicit.iterations
    assert plan_pol.e2e_latency == explicit.e2e_latency


def test_search_accepts_per_pool_policies():
    model = small_model()
    search = ApexSearch(model, h100_node(4))
    reqs = get_trace("chat", arrival_rate=4.0, seed=0, num_requests=16)
    res = search.search(reqs, feasible_only=True, disaggregated=True,
                        max_disagg_plans=8,
                        prefill_policy=BatchingPolicy(chunked_prefill=128))
    assert res.best.feasible
    assert any(r.plan_label.startswith("disagg[")
               for r in res.all_reports)


# ---------------------------------------------------------------------------
# SearchResult.top() honors the search's SLO filters
# ---------------------------------------------------------------------------

def _mk_report(label, e2e, ttft, tput=0.0):
    return SimulationReport(
        plan_label=label, e2e_latency=e2e, total_energy=1.0,
        ttft_mean=ttft, ttft_p95=ttft, tpot_mean=0, tpot_p95=0,
        latency_p95=0, throughput_tok_s=tput, mfu=0, mbu=0, iterations=1,
        preemptions=0, peak_kv_tokens=1, peak_batch=1, feasible=True)


def test_top_applies_slo_filters():
    fast_bad_ttft = _mk_report("fast-bad", e2e=1.0, ttft=9.0)
    slow_good_ttft = _mk_report("slow-good", e2e=2.0, ttft=0.1)
    res = SearchResult(best=slow_good_ttft, best_plan=None,
                       all_reports=[fast_bad_ttft, slow_good_ttft],
                       num_schemes=2, num_feasible=2, search_seconds=0.0,
                       objective="latency", slo_ttft_s=1.0)
    top = res.top(5)
    # the SLO-violating plan the search rejected never surfaces
    assert [r.plan_label for r in top] == ["slow-good"]
    # without SLOs it would have ranked first
    res_free = SearchResult(best=fast_bad_ttft, best_plan=None,
                            all_reports=[fast_bad_ttft, slow_good_ttft],
                            num_schemes=2, num_feasible=2,
                            search_seconds=0.0, objective="latency")
    assert res_free.top(1)[0].plan_label == "fast-bad"


def test_throughput_objective_ranks_higher_tok_s_first():
    lo = _mk_report("lo", e2e=1.0, ttft=0.1, tput=100.0)
    hi = _mk_report("hi", e2e=2.0, ttft=0.1, tput=900.0)
    key = OBJECTIVES["throughput"]
    assert key(hi) < key(lo)
    res = SearchResult(best=hi, best_plan=None, all_reports=[lo, hi],
                       num_schemes=2, num_feasible=2, search_seconds=0.0,
                       objective="throughput")
    assert res.top(1)[0].plan_label == "hi"


def test_search_throughput_objective_end_to_end():
    model = small_model()
    search = ApexSearch(model, h100_node(4))
    reqs = get_trace("chat", arrival_rate=4.0, seed=0, num_requests=16)
    res = search.search(reqs, objective="throughput", feasible_only=True)
    feas = [r for r in res.all_reports if r.feasible]
    assert res.best.throughput_tok_s == max(r.throughput_tok_s
                                            for r in feas)


# ---------------------------------------------------------------------------
# shared metrics + drain-rate derivation
# ---------------------------------------------------------------------------

def test_percentile_and_p95():
    xs = [float(i) for i in range(1, 101)]
    assert percentile(xs, 0.95) == 95.0
    assert p95(xs) == 95.0
    assert percentile([], 0.5) == 0.0
    assert percentile([7.0], 0.99) == 7.0
    assert percentile(xs, 0.5) == 50.0


def test_infeasible_report_canonical():
    rep = SimulationReport.infeasible("nope")
    assert not rep.feasible
    assert rep.plan_label == "nope"
    assert rep.e2e_latency == float("inf")
    assert rep.total_energy == float("inf")
    # ranked last by every minimizing objective
    real = _mk_report("ok", e2e=1.0, ttft=0.1)
    assert OBJECTIVES["latency"](rep) > OBJECTIVES["latency"](real)


def test_derive_drain_rate():
    assert derive_drain_rate(2048.0, 0.5, fallback=1.0) == pytest.approx(
        4096.0)
    assert derive_drain_rate(2048.0, 0.0, fallback=123.0) == 123.0
    assert derive_drain_rate(0.0, 1.0, fallback=7.0) == 7.0
