"""Per-architecture smoke tests: reduced configs, one forward + one train
step on CPU, asserting output shapes and finiteness; decode consistency."""

import jax
import jax.numpy as jnp
import pytest

from repro import configs as C
from repro.launch.steps import make_train_step
from repro.models import (decode_step, encdec_forward, forward, init_cache,
                          init_encdec_params, init_params)
from repro.training.optimizer import adamw_init

RNG = jax.random.PRNGKey(0)


def _finite(x):
    return bool(jnp.isfinite(jnp.asarray(x, jnp.float32)).all())


@pytest.mark.parametrize("arch", C.ARCHS)
def test_smoke_forward(arch):
    cfg = C.get_reduced(arch)
    cfg.validate()
    B, S = 2, 16
    if cfg.encoder is not None:
        params = init_encdec_params(RNG, cfg)
        frames = jax.random.normal(RNG, (B, 12, cfg.d_model), jnp.float32)
        toks = jnp.ones((B, S), jnp.int32)
        logits = encdec_forward(params, cfg, frames, toks)
    else:
        params = init_params(RNG, cfg)
        if cfg.embeds_input:
            emb = jax.random.normal(RNG, (B, S, cfg.d_model), jnp.float32)
            logits = forward(params, cfg, embeds=emb)
        else:
            toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
            logits = forward(params, cfg, tokens=toks)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert _finite(logits)


@pytest.mark.parametrize("arch", C.ARCHS)
def test_smoke_train_step(arch):
    cfg = C.get_reduced(arch)
    B, S = 2, 16
    if cfg.encoder is not None:
        params = init_encdec_params(RNG, cfg)
    else:
        params = init_params(RNG, cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, microbatches=1, remat=True))
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(RNG, (B, 12, cfg.d_model),
                                            jnp.float32)
    elif cfg.embeds_input:
        batch["embeds"] = jax.random.normal(RNG, (B, S, cfg.d_model),
                                            jnp.float32)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert _finite(metrics["loss"])
    assert float(metrics["loss"]) > 0
    assert int(new_opt.step) == 1
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["internlm2_1_8b", "gemma3_12b",
                                  "mixtral_8x7b", "deepseek_v2_lite_16b",
                                  "mamba2_2_7b", "zamba2_7b",
                                  "qwen1_5_32b"])
def test_decode_matches_forward(arch):
    cfg = C.get_reduced(arch)
    params = init_params(RNG, cfg)
    B, S = 1, 10
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                              cfg.vocab_size)
    full = forward(params, cfg, tokens=toks).astype(jnp.float32)
    cache = init_cache(cfg, B, max_len=32)
    outs = []
    for t in range(S):
        lg, cache = decode_step(params, cfg, toks[:, t:t + 1], cache)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    rel = float(jnp.abs(full - dec).max() / (jnp.abs(full).max() + 1e-9))
    assert rel < 0.05      # bf16 accumulation-order differences only


def test_param_counts_match_ir():
    """The JAX model and the APEX IR agree on parameter counts."""
    from repro.models import param_count
    for arch in ["internlm2_1_8b", "mixtral_8x7b", "mamba2_2_7b"]:
        cfg = C.get_reduced(arch)
        params = init_params(RNG, cfg)
        n_jax = param_count(params)
        n_ir = cfg.to_ir().total_params()
        # IR omits norms / small vectors; agreement within 5%
        assert abs(n_jax - n_ir) / n_jax < 0.05, (arch, n_jax, n_ir)


def test_full_config_ir_sizes():
    """Full assigned configs produce sane parameter counts (billions)."""
    expect = {"gemma3_12b": (10, 16), "qwen1_5_32b": (28, 36),
              "mixtral_8x7b": (40, 52), "mamba2_2_7b": (2.2, 3.2),
              "deepseek_v2_lite_16b": (12, 18)}
    for arch, (lo, hi) in expect.items():
        n = C.get_config(arch).to_ir().total_params() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.1f}B outside [{lo},{hi}]"
